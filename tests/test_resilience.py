"""Elastic resilience runtime tests: ResilienceSession state sharing,
on-device recovery (fused compiled step), elastic re-assignment, the
straggler scenario protocol, and the PR's satellite fixes.

Multi-round MESH tests follow the repo's forced-host-device pattern
(subprocess with XLA_FLAGS, like tests/test_distributed_executor.py) so the
in-process suite keeps its single-device assumptions and tier-1 stays fast.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _pts(n=160, d=3, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


# ------------------------------------------------------ satellite: adversary


def _adversarial_reference(assignment, t):
    """The pre-vectorization scalar greedy (kept verbatim as the oracle)."""
    A = assignment.matrix.astype(np.int64)
    alive = np.ones(assignment.num_nodes, dtype=bool)
    for _ in range(min(t, assignment.num_nodes - 1)):
        best_node, best_key = None, None
        cover = A[alive].sum(axis=0)
        for i in np.flatnonzero(alive):
            c = cover - A[i]
            key = (int(c.min()), -int((c == c.min()).sum()), -int(A[i].sum()))
            if best_key is None or key < best_key:
                best_key, best_node = key, i
        alive[best_node] = False
    return alive


def test_adversarial_vectorized_matches_reference():
    from repro.core import (
        adversarial_stragglers,
        bernoulli_assignment,
        cyclic_assignment,
        fractional_repetition_assignment,
        singleton_assignment,
    )

    cases = [
        cyclic_assignment(37, 9, 3),
        fractional_repetition_assignment(24, 8, 2),
        singleton_assignment(20, 6),
    ]
    for seed in range(4):
        cases.append(
            bernoulli_assignment(30, 7, ell=2.5, rng=np.random.default_rng(seed))
        )
    for a in cases:
        for t in (0, 1, 2, 3):
            got = adversarial_stragglers(a, t)
            want = _adversarial_reference(a, t)
            np.testing.assert_array_equal(got, want, err_msg=f"{a.scheme} t={t}")


# ------------------------------------------------- satellite: nnls degeneracy


def _degenerate_nnls_assignment():
    """NNLS pins b_0 to exactly 0 here: serving shard 0 (unique to node 0)
    costs more over-coverage on the 4 triple-replicated shards than it saves
    (KKT multiplier at the boundary), so covered shard 0 ends with zero mass."""
    from repro.core.assignment import Assignment

    mat = np.zeros((3, 13), dtype=np.uint8)
    mat[0, 0] = 1      # shard 0: node 0 only
    mat[:, 1:5] = 1    # shards 1-4: everyone
    mat[1, 5:9] = 1    # shards 5-8: node 1 only
    mat[2, 9:13] = 1   # shards 9-12: node 2 only
    return Assignment(matrix=mat, scheme="crafted", params={})


def test_nnls_degenerate_is_explicitly_infeasible():
    from repro.core.recovery import nnls_recovery

    a = _degenerate_nnls_assignment()
    res = nnls_recovery(a, np.ones(3, dtype=bool))
    assert res.method == "nnls"
    assert res.feasible is False
    assert res.a[0] <= 1e-12  # the raw, unscaled b came back


def test_solve_recovery_auto_skips_degenerate_nnls_to_lp():
    from repro.core.recovery import solve_recovery

    a = _degenerate_nnls_assignment()
    res = solve_recovery(a, np.ones(3, dtype=bool), method="auto")
    assert res.method == "lp"
    assert res.feasible
    assert res.a.min() >= 1.0 - 1e-7


# ------------------------------------- satellite: simulator reset/determinism


def test_deadline_simulator_determinism_and_reset():
    from repro.core import DeadlineStragglerSimulator

    kw = dict(num_nodes=7, seed=11, p_spike=0.3, persistence=0.7)
    s1 = DeadlineStragglerSimulator(**kw)
    s2 = DeadlineStragglerSimulator(**kw)
    run1 = [s1.step() for _ in range(8)]
    run2 = [s2.step() for _ in range(8)]
    for r1, r2 in zip(run1, run2):  # same seed → same stream
        np.testing.assert_array_equal(r1.alive, r2.alive)
        np.testing.assert_array_equal(r1.spiked, r2.spiked)
        np.testing.assert_allclose(r1.latencies, r2.latencies)
    s1.reset()
    replay = [s1.step() for _ in range(8)]
    for r1, r2 in zip(run1, replay):  # reset → replay
        np.testing.assert_array_equal(r1.alive, r2.alive)
        np.testing.assert_array_equal(r1.spiked, r2.spiked)
        assert r1.index == r2.index


def test_step_record_carries_spike_state():
    from repro.core import DeadlineStragglerSimulator

    kw = dict(num_nodes=5, seed=0, p_spike=0.5, persistence=1.0)
    sim = DeadlineStragglerSimulator(**kw)
    recs = [sim.step() for _ in range(6)]
    assert any(r.spiked.any() for r in recs)
    # The record owns a SNAPSHOT: mutating it must not corrupt the stream.
    recs[2].spiked[:] = ~recs[2].spiked
    tail = [sim.step() for _ in range(3)]
    ref = DeadlineStragglerSimulator(**kw)
    for _ in range(6):
        ref.step()
    for got, want in zip(tail, [ref.step() for _ in range(3)]):
        np.testing.assert_array_equal(got.spiked, want.spiked)
        np.testing.assert_array_equal(got.alive, want.alive)


# ------------------------------------------------------- scenario protocol


def test_scenario_factory_and_reset_replay():
    from repro.core import cyclic_assignment, make_scenario

    a = cyclic_assignment(24, 6, 2)
    for name, kw in (
        ("iid", {"p_straggler": 0.3, "seed": 2}),
        ("fixed", {"t": 2, "seed": 2}),
        ("deadline", {"seed": 2, "p_spike": 0.3}),
    ):
        scen = make_scenario(name, 6, **kw)
        first = [next(scen) for _ in range(5)]
        scen.reset()
        again = [next(scen) for _ in range(5)]
        for r1, r2 in zip(first, again):
            np.testing.assert_array_equal(r1.alive, r2.alive)
            assert r1.index == r2.index
        assert first[0].alive.shape == (6,)

    adv = make_scenario("adversarial", 6, assignment=a, t=1)
    s1, s2 = next(adv), next(adv)
    np.testing.assert_array_equal(s1.alive, s2.alive)  # stateless adversary
    with pytest.raises(ValueError, match="assignment"):
        make_scenario("adversarial", 6)
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("lunch-break", 6)


# ------------------------------------------------ scenario: trace replay


def test_trace_scenario_roundtrip_reset_and_loop(tmp_path):
    from repro.core import TraceScenario, make_scenario, record_trace

    path = str(tmp_path / "trace.jsonl")
    src = make_scenario("deadline", 5, seed=3, p_spike=0.3)
    assert record_trace(src, 6, path) == 6
    scen = make_scenario("trace", 5, path=path)
    src.reset()
    first = [next(scen) for _ in range(6)]
    for got, want in zip(first, [next(src) for _ in range(6)]):
        np.testing.assert_array_equal(got.alive, want.alive)
        np.testing.assert_allclose(got.latencies, want.latencies)
    # Infinite iterator: wraps around past the recorded rounds...
    np.testing.assert_array_equal(next(scen).alive, first[0].alive)
    assert next(scen).index == 7
    # ...and reset() replays from step 0.
    scen.reset()
    again = [next(scen) for _ in range(6)]
    for r1, r2 in zip(first, again):
        np.testing.assert_array_equal(r1.alive, r2.alive)
        assert r1.index == r2.index
    # loop=False yields exactly the recorded rounds.
    finite = TraceScenario(5, path, loop=False)
    assert len(list(finite)) == 6
    assert len(finite) == 6


def test_trace_scenario_ignores_extra_row_keys(tmp_path):
    """BENCH-row-style annotations (name/us_per_call/derived) ride along."""
    from repro.core import TraceScenario

    path = tmp_path / "annotated.jsonl"
    path.write_text(
        '{"name": "scen_cell", "us_per_call": 1.0, "derived": "x", "alive": [1, 0, 1]}\n'
        '{"alive": [0, 1, 1], "index": 7}\n'
    )
    scen = TraceScenario(3, str(path))
    np.testing.assert_array_equal(next(scen).alive, [True, False, True])
    np.testing.assert_array_equal(next(scen).alive, [False, True, True])


def test_trace_scenario_input_validation(tmp_path):
    import pytest as _pytest

    from repro.core import TraceScenario, make_scenario

    bad_len = tmp_path / "bad_len.jsonl"
    bad_len.write_text('{"alive": [1, 0]}\n')
    with _pytest.raises(ValueError, match="entries"):
        TraceScenario(3, str(bad_len))
    no_alive = tmp_path / "no_alive.jsonl"
    no_alive.write_text('{"latencies": [1.0]}\n')
    with _pytest.raises(ValueError, match="'alive'"):
        TraceScenario(1, str(no_alive))
    empty = tmp_path / "empty.jsonl"
    empty.write_text("\n")
    with _pytest.raises(ValueError, match="empty trace"):
        TraceScenario(1, str(empty))
    with _pytest.raises(ValueError, match="path="):
        make_scenario("trace", 3)


# --------------------------------------------------- session: shared cache


def test_session_one_cache_across_algorithms_and_plan():
    from repro.core import ResilienceSession, cyclic_assignment, fixed_count_stragglers

    pts = _pts(120)
    a = cyclic_assignment(120, 6, 2)
    alive = fixed_count_stragglers(6, 1, np.random.default_rng(3))
    sess = ResilienceSession(a)
    out = sess.kmedian(pts, 3, alive, local_iters=3, coord_iters=4)
    sess.cost(pts, out.centers, alive)
    sess.pca(pts, 2, 0.5, alive)
    sess.coreset(pts, 3, 16, alive)
    assert sess.stats.host_solves == 1  # one pattern, solved once, shared 4×
    assert sess.stats.cache_hits == 3


def test_coverage_validation_computed_once_per_pattern():
    """Satellite fix: the per-call shard-coverage re-validation in the
    algorithm prelude is hoisted into the session and cached per pattern —
    repeated streaming solves against a seen pattern skip the host-side
    work.  ``SessionStats.coverage_checks`` counts actual computations."""
    from repro.core import ResilienceSession, cyclic_assignment

    pts = _pts(90)
    a = cyclic_assignment(90, 6, 2)
    alive = np.array([True, True, False, True, True, True])
    sess = ResilienceSession(a)
    sess.coreset(pts, 3, 8, alive)
    sess.coreset(pts, 3, 8, alive)
    sess.kmedian(pts, 3, alive, local_iters=2, coord_iters=2)
    assert sess.stats.coverage_checks == 1  # one pattern → one validation
    other = np.array([True, False, True, True, True, True])
    sess.cost(pts, np.zeros((3, 3), np.float32), other)
    assert sess.stats.coverage_checks == 2  # new pattern → one more
    sess.coreset(pts, 3, 8, other)
    assert sess.stats.coverage_checks == 2
    # The all-dead guard still fires (now from the cached validation).
    with pytest.raises(ValueError, match="no surviving"):
        sess.prepare(pts, np.zeros(6, dtype=bool))


def test_coverage_validation_invalidated_with_pattern_cache():
    """An elastic patch drops exactly the coverage entries it can change —
    the same rule as the recovery cache."""
    from repro.core import ElasticPolicy, ResilienceSession, cyclic_assignment

    sess = ResilienceSession(
        cyclic_assignment(40, 8, 2), elastic=ElasticPolicy(enabled=True, patience=2)
    )
    dead_67 = np.ones(8, dtype=bool)
    dead_67[[6, 7]] = False
    uncovered_before = sess.validate_coverage(dead_67)
    assert len(uncovered_before) > 0  # adjacent cyclic nodes → coverage lost
    assert sess.stats.coverage_checks == 1
    for _ in range(3):
        sess.observe(dead_67)
    assert sess.stats.elastic_patches >= 1
    # The patch re-replicated the at-risk shards onto nodes alive in this
    # pattern → the stale entry must be recomputed, and is now covered.
    assert len(sess.validate_coverage(dead_67)) == 0
    assert sess.stats.coverage_checks == 2


def test_coverage_entry_from_caller_rec_also_invalidated():
    """A coverage entry seeded via validate_coverage(alive, rec=...) never
    touches the recovery cache — the patch sweep must still drop it (it is
    keyed independently), or it would serve pre-patch uncovered ids."""
    from repro.core import ElasticPolicy, ResilienceSession, cyclic_assignment
    from repro.core.recovery import solve_recovery

    a = cyclic_assignment(40, 8, 2)
    sess = ResilienceSession(a, elastic=ElasticPolicy(enabled=True, patience=2))
    dead = np.ones(8, dtype=bool)
    dead[[6, 7]] = False
    rec = solve_recovery(a, dead)  # host-side, bypasses sess._cache
    assert len(sess.validate_coverage(dead, rec)) > 0
    assert sess.stats.host_solves == 0  # cache really was bypassed
    for _ in range(3):
        sess.observe(dead)
    assert sess.stats.elastic_patches >= 1
    assert len(sess.validate_coverage(dead)) == 0  # recomputed post-patch
    assert sess.stats.coverage_checks == 2


def test_entry_points_without_session_unchanged():
    """session=None must reproduce the old per-call behaviour exactly."""
    from repro.core import (
        cyclic_assignment,
        fixed_count_stragglers,
        resilient_kmedian,
    )

    pts = _pts(100, seed=5)
    a = cyclic_assignment(100, 5, 2)
    alive = fixed_count_stragglers(5, 1, np.random.default_rng(1))
    o1 = resilient_kmedian(pts, 3, a, alive, local_iters=3, coord_iters=4)
    o2 = resilient_kmedian(pts, 3, a, alive, local_iters=3, coord_iters=4)
    assert o1.cost == pytest.approx(o2.cost)


def test_training_plan_rides_the_session_cache():
    from repro.train.resilient import make_plan

    plan = make_plan(6, 6, redundancy=2, scheme="cyclic")
    alive = np.array([True, True, False, True, True, True])
    plan.group_weights(alive)
    plan.group_weights(alive)
    plan.recovery(alive)
    assert plan.session.stats.host_solves == 1
    assert plan.session.stats.cache_hits == 2


# ---------------------------------------- on-device recovery (satellite 4)


def test_jax_recovery_masked_parity_with_lp():
    """Device-solver weights must land in the LP's feasibility band (within
    tolerance) on all three construction families."""
    from repro.core import (
        bernoulli_assignment,
        cyclic_assignment,
        fixed_count_stragglers,
        fractional_repetition_assignment,
        jax_recovery_masked,
        lp_recovery,
    )

    rng = np.random.default_rng(0)
    cases = [
        cyclic_assignment(60, 8, 3),
        fractional_repetition_assignment(64, 8, 2),
        bernoulli_assignment(60, 10, ell=4.0, rng=rng),
    ]
    for a in cases:
        alive = fixed_count_stragglers(a.num_nodes, 2, rng)
        lp = lp_recovery(a, alive)
        b = np.asarray(
            jax_recovery_masked(a.matrix.astype(np.float32), alive, iters=500)
        )
        assert (b[~alive] == 0).all(), "stragglers must get zero weight"
        ach = b @ a.matrix
        covered = a.matrix[alive].sum(axis=0) > 0
        if lp.feasible:
            assert ach[covered].min() >= 1.0 - 1e-3, a.scheme
            # Heuristic band: within a constant factor of the LP optimum.
            assert ach[covered].max() <= 4.0 * (1.0 + lp.delta), a.scheme


def test_jax_recovery_masked_uncovered_shard_pattern():
    from repro.core import jax_recovery_masked, lp_recovery, singleton_assignment

    a = singleton_assignment(30, 6)
    alive = np.array([True, True, False, True, True, True])
    lp = lp_recovery(a, alive)
    assert len(lp.uncovered) > 0
    b = np.asarray(jax_recovery_masked(a.matrix.astype(np.float32), alive, iters=300))
    ach = b @ a.matrix
    covered = a.matrix[alive].sum(axis=0) > 0
    assert np.isfinite(b).all()
    assert (ach[~covered] == 0).all()  # lost shards stay lost, no NaN/Inf
    assert ach[covered].min() >= 1.0 - 1e-3  # covered band still achieved
    np.testing.assert_array_equal(np.flatnonzero(~covered), lp.uncovered)


def test_step_cost_no_host_solve_no_recompile_lemma3_band():
    """The fused path: unseen straggler patterns are runtime data — zero host
    solves, zero re-lowers, and the estimate stays in the Lemma-3 band."""
    import jax
    import jax.numpy as jnp

    from repro.core import (
        ResilienceSession,
        clustering_cost,
        cyclic_assignment,
        fixed_count_stragglers,
        lloyd,
    )
    from repro.core.executor import get_executor

    pts = _pts(150, seed=7)
    a = cyclic_assignment(150, 6, 2)  # δ = 0 band for any single straggler
    centers = np.asarray(
        lloyd(jax.random.PRNGKey(0), jnp.asarray(pts), 3, iters=4).centers
    )
    true = float(clustering_cost(jnp.asarray(pts), jnp.asarray(centers)))
    sess = ResilienceSession(a)
    ex = get_executor(None)
    est0 = sess.step_cost(pts, centers, fixed_count_stragglers(6, 1, np.random.default_rng(0)))
    n_compiled = len(ex._jitted)
    for seed in (1, 2, 3):  # three more previously-unseen patterns
        alive = fixed_count_stragglers(6, 1, np.random.default_rng(seed))
        est = sess.step_cost(pts, centers, alive)
        assert true * (1 - 1e-4) <= est <= true * 1.5
    assert len(ex._jitted) == n_compiled, "new pattern must not re-lower"
    assert sess.stats.host_solves == 0
    assert sess.stats.device_solves == 4
    assert true * (1 - 1e-4) <= est0 <= true * 1.5


# ----------------------------------------------------- elastic re-assignment


def _persistent_spike_scenario(s=8, seed=6):
    from repro.core import make_scenario

    # persistence=1.0: spiked nodes never recover — the elastic regime.
    return make_scenario(
        "deadline", s, seed=seed, p_spike=0.06, persistence=1.0,
        spike_scale=6.0, deadline=2.0,
    )


def test_elastic_repairs_coverage_disabled_loses_it():
    from repro.core import ElasticPolicy, ResilienceSession, cyclic_assignment

    def run(enabled):
        sess = ResilienceSession(
            cyclic_assignment(160, 8, 2),
            elastic=ElasticPolicy(enabled=enabled, patience=2),
        )
        scen = _persistent_spike_scenario()
        uncovered = [sess.observe(next(scen))["uncovered"] for _ in range(16)]
        return sess, uncovered

    s_on, u_on = run(True)
    s_off, u_off = run(False)
    assert s_on.stats.elastic_patches >= 1
    assert all(u == 0 for u in u_on[-6:]), f"elastic must restore coverage: {u_on}"
    assert any(u > 0 for u in u_off[-6:]), f"disabled run must report loss: {u_off}"
    assert s_off.stats.uncovered_rounds > s_on.stats.uncovered_rounds


def test_elastic_patch_invalidates_only_affected_patterns():
    from repro.core import ElasticPolicy, ResilienceSession, cyclic_assignment

    sess = ResilienceSession(
        cyclic_assignment(40, 8, 2), elastic=ElasticPolicy(enabled=True, patience=2)
    )
    # Prime the host cache: one pattern with every healthy node alive, one
    # with ALL potential patch targets (nodes 0..5) dead.
    dead_67 = np.ones(8, dtype=bool)
    dead_67[[6, 7]] = False
    only_67 = ~dead_67
    sess.recovery(dead_67)
    sess.recovery(only_67)
    assert sess.stats.host_solves == 2
    # Persistent stragglers 6, 7 → patch re-replicates their shards onto the
    # healthy nodes 0..5.
    for _ in range(3):
        sess.observe(dead_67)
    assert sess.stats.elastic_patches >= 1
    # dead_67 has patched nodes alive → its cached result is stale → dropped;
    # only_67 has every patched node dead (b=0 there, the new matrix entries
    # never enter bᵀA_R) → it must SURVIVE the patch.
    solves_before, hits_before = sess.stats.host_solves, sess.stats.cache_hits
    sess.recovery(only_67)
    assert sess.stats.cache_hits == hits_before + 1, "unaffected entry was dropped"
    res = sess.recovery(dead_67)
    assert sess.stats.host_solves == solves_before + 1, "stale entry was kept"
    assert res.feasible and len(res.uncovered) == 0


def test_elastic_patch_repairs_recovery_after_coverage_loss():
    """After the patch, the pattern that used to lose shards becomes exactly
    recoverable (the re-replicated shards have live replicas)."""
    from repro.core import ElasticPolicy, ResilienceSession, cyclic_assignment

    a = cyclic_assignment(40, 8, 2)
    sess = ResilienceSession(a, elastic=ElasticPolicy(enabled=True, patience=2))
    dead = np.ones(8, dtype=bool)
    dead[[6, 7]] = False  # adjacent under cyclic ell=2 → coverage lost
    assert len(sess.recovery(dead).uncovered) > 0
    for _ in range(3):
        sess.observe(dead)
    assert sess.stats.elastic_patches >= 1
    assert sess.assignment.scheme.endswith("+elastic")
    res = sess.recovery(dead)
    assert len(res.uncovered) == 0 and res.feasible


def test_step_cost_tracks_dataset_switches():
    """The resident device placement must follow the points argument even
    when host-path calls (cost/prepare) repack a different dataset between
    step_cost calls — regression for a stale-resident aliasing bug."""
    import jax
    import jax.numpy as jnp

    from repro.core import ResilienceSession, cyclic_assignment, lloyd

    a = cyclic_assignment(80, 4, 2)
    pts_a = _pts(80, seed=1)
    pts_b = pts_a + 100.0  # wildly different cost against the same centers
    centers = np.asarray(
        lloyd(jax.random.PRNGKey(0), jnp.asarray(pts_a), 2, iters=3).centers
    )
    alive = np.array([True, True, True, False])
    sess = ResilienceSession(a)
    est_a = sess.step_cost(pts_a, centers, alive)
    sess.cost(pts_b, centers, alive)  # host path repacks for pts_b
    est_b = sess.step_cost(pts_b, centers, alive)
    fresh = ResilienceSession(a).step_cost(pts_b, centers, alive)
    assert est_b == pytest.approx(fresh, rel=1e-6)
    assert est_b > 10 * est_a  # and definitely not pts_a's cost


def test_in_place_mutation_invalidates_pack_cache():
    """Identity-keyed caching must not survive an in-place edit of the
    caller's points array (content fingerprint regression)."""
    import jax
    import jax.numpy as jnp

    from repro.core import ResilienceSession, cyclic_assignment, lloyd

    a = cyclic_assignment(80, 4, 2)
    pts = _pts(80, seed=2)
    centers = np.asarray(
        lloyd(jax.random.PRNGKey(0), jnp.asarray(pts), 2, iters=3).centers
    )
    alive = np.array([True, True, False, True])
    sess = ResilienceSession(a)
    est1 = sess.step_cost(pts, centers, alive)
    c1 = sess.cost(pts, centers, alive)
    pts *= 3.0  # in-place: same object, new contents
    est2 = sess.step_cost(pts, centers, alive)
    c2 = sess.cost(pts, centers, alive)
    fresh = ResilienceSession(a)
    assert est2 == pytest.approx(fresh.step_cost(pts, centers, alive), rel=1e-6)
    assert c2 == pytest.approx(fresh.cost(pts, centers, alive), rel=1e-6)
    assert est2 != pytest.approx(est1, rel=1e-3)
    assert c2 != pytest.approx(c1, rel=1e-3)


def test_session_rejects_foreign_assignment_and_executor():
    from repro.core import (
        ElasticPolicy,
        ResilienceSession,
        cyclic_assignment,
        resilient_cost,
        resilient_kmedian,
    )

    pts = _pts(40, seed=4)
    a = cyclic_assignment(40, 8, 2)
    other = cyclic_assignment(40, 8, 3)  # same node count, different matrix
    sess = ResilienceSession(a, elastic=ElasticPolicy(enabled=True, patience=2))
    alive = np.ones(8, dtype=bool)
    with pytest.raises(ValueError, match="not the session's assignment"):
        resilient_kmedian(pts, 2, other, alive, session=sess,
                          local_iters=2, coord_iters=2)
    with pytest.raises(ValueError, match="conflicts with the session's"):
        resilient_cost(pts, np.zeros((2, 3), np.float32), a, alive,
                       session=sess, executor="mesh")
    # The ORIGINAL assignment stays accepted after an elastic patch (lineage).
    dead = alive.copy()
    dead[[6, 7]] = False
    for _ in range(3):
        sess.observe(dead)
    assert sess.stats.elastic_patches >= 1
    assert sess.assignment is not a
    est = resilient_cost(pts, np.zeros((2, 3), np.float32), a, dead, session=sess)
    assert np.isfinite(est)


def test_step_cost_all_dead_raises():
    from repro.core import ResilienceSession, cyclic_assignment

    sess = ResilienceSession(cyclic_assignment(40, 4, 2))
    with pytest.raises(ValueError, match="no surviving"):
        sess.step_cost(_pts(40), np.zeros((2, 3), np.float32), np.zeros(4, bool))


def test_recovery_method_conflict_with_session_raises():
    from repro.core import ResilienceSession, cyclic_assignment, resilient_kmedian

    a = cyclic_assignment(60, 6, 2)
    sess = ResilienceSession(a, recovery_method="lp")
    alive = np.array([True] * 5 + [False])
    with pytest.raises(ValueError, match="conflicts with the session"):
        resilient_kmedian(
            _pts(60), 3, a, alive, recovery_method="uniform", session=sess
        )
    # Explicitly matching (or omitted) methods are fine.
    out = sess.kmedian(_pts(60), 3, alive, local_iters=2, coord_iters=2,
                       recovery_method="lp")
    assert np.isfinite(out.cost)


def _skewed_assignment():
    """Max load 8 on nodes 0/1; nodes 6/7 exclusively hold shards 16–19.
    Killing 6 and 7 puts those shards at risk, and the patch targets (the
    least-loaded healthy nodes 4/5, load 4 → ≤ 8) fit inside the existing
    padding — exercising the INCREMENTAL re-pack/re-place branch."""
    from repro.core.assignment import Assignment

    mat = np.zeros((8, 20), dtype=np.uint8)
    mat[0, 0:8] = 1
    mat[1, 8:16] = 1
    mat[2, 0:8] = 1
    mat[3, 8:16] = 1
    mat[4, 0:4] = 1
    mat[5, 4:8] = 1
    mat[6, 16:20] = 1
    mat[7, 16:20] = 1
    return Assignment(matrix=mat, scheme="skewed", params={})


def test_patch_does_not_mutate_handed_out_pack():
    """Arrays returned by prepare() must stay stable across an elastic patch
    (copy-on-patch), or a caller's in-flight algorithm would see mixed
    pre-/post-patch placements."""
    from repro.core import ElasticPolicy, ResilienceSession
    from repro.core.kmedian import prepare_resilient_run

    pts = _pts(20, seed=3)
    sess = ResilienceSession(
        _skewed_assignment(), elastic=ElasticPolicy(enabled=True, patience=2)
    )
    dead = np.ones(8, dtype=bool)
    dead[[6, 7]] = False
    # Make the pack + placement resident, then hand out the host arrays.
    sess.step_cost(pts, np.zeros((2, 3), np.float32), dead)
    _, _, _, _, xs, ws = prepare_resilient_run(pts, None, dead, session=sess)
    xs_snap, ws_snap = xs.copy(), ws.copy()
    for _ in range(3):
        sess.observe(dead)
    assert sess.stats.elastic_patches >= 1
    assert sess.stats.moved_node_blocks >= 1, "incremental branch did not run"
    np.testing.assert_array_equal(xs, xs_snap)
    np.testing.assert_array_equal(ws, ws_snap)
    # The session's own view DID move on: fresh arrays with the re-replicated
    # shards now weighted on the patch-target nodes.
    _, _, _, _, xs2, ws2 = prepare_resilient_run(pts, None, dead, session=sess)
    assert xs2 is not xs
    assert ws2[[4, 5]].sum() > ws[[4, 5]].sum()


def test_executor_update_node_rows_local():
    from repro.core.executor import get_executor

    ex = get_executor(None)
    arr = ex.place_node_stacked(np.arange(12, dtype=np.float32).reshape(6, 2))
    out = np.asarray(ex.update_node_rows(arr, [0, 3], np.full((2, 2), 9.0, np.float32)))
    want = np.arange(12, dtype=np.float32).reshape(6, 2)
    want[[0, 3]] = 9.0
    np.testing.assert_array_equal(out, want)


def test_executor_update_node_rows_mesh_single_device():
    from repro.core.executor import get_executor

    ex = get_executor("mesh")
    arr = ex.place_node_stacked(np.arange(12, dtype=np.float32).reshape(6, 2))
    out = np.asarray(ex.update_node_rows(arr, [1, 4], np.full((2, 2), 7.0, np.float32)))
    want = np.arange(12, dtype=np.float32).reshape(6, 2)
    want[[1, 4]] = 7.0
    np.testing.assert_array_equal(out, want)


def test_session_mesh_matches_local_single_device():
    import jax
    import jax.numpy as jnp

    from repro.core import ResilienceSession, cyclic_assignment, fixed_count_stragglers, lloyd

    pts = _pts(140, seed=9)
    a = cyclic_assignment(140, 6, 2)
    alive = fixed_count_stragglers(6, 1, np.random.default_rng(4))
    centers = np.asarray(
        lloyd(jax.random.PRNGKey(1), jnp.asarray(pts), 3, iters=4).centers
    )
    sl = ResilienceSession(a)
    sm = ResilienceSession(a, executor="mesh")
    cl = sl.step_cost(pts, centers, alive)
    cm = sm.step_cost(pts, centers, alive)
    assert cm == pytest.approx(cl, rel=1e-5)
    kl = sl.kmedian(pts, 3, alive, local_iters=3, coord_iters=4)
    km = sm.kmedian(pts, 3, alive, local_iters=3, coord_iters=4)
    assert km.cost == pytest.approx(kl.cost, rel=1e-5)


# --------------------------------------- multi-round mesh run (8 devices)


def test_multiround_session_parity_8_devices():
    """Forced-host-device pattern: a full multi-round elastic run — scenario
    stream, per-round fused step_cost, mid-run re-assignment with block
    re-placement — must agree local↔mesh at 1e-5 per round, with zero host
    solves on the hot path and zero uncovered shards after the patch."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax
        import jax.numpy as jnp
        assert jax.device_count() == 8
        from repro.core import (ResilienceSession, ElasticPolicy,
                                cyclic_assignment, lloyd, make_scenario)
        rng = np.random.default_rng(0)
        pts = rng.normal(size=(160, 3)).astype(np.float32)
        centers = np.asarray(lloyd(jax.random.PRNGKey(0), jnp.asarray(pts), 3,
                                   iters=4).centers)
        def run(executor):
            sess = ResilienceSession(
                cyclic_assignment(160, 8, 2), executor=executor,
                elastic=ElasticPolicy(enabled=True, patience=2))
            scen = make_scenario("deadline", 8, seed=6, p_spike=0.06,
                                 persistence=1.0, spike_scale=6.0, deadline=2.0)
            costs, uncovered = [], []
            for _ in range(12):
                step = next(scen)
                ev = sess.observe(step)
                uncovered.append(ev["uncovered"])
                if step.alive.any():
                    costs.append(sess.step_cost(pts, centers, step.alive))
            return sess, costs, uncovered
        sl, cl, ul = run("local")
        sm, cm, um = run("mesh")
        assert ul == um, (ul, um)
        for a, b in zip(cl, cm):
            assert abs(a / b - 1.0) <= 1e-5, (a, b)
        assert sl.stats.host_solves == 0 and sm.stats.host_solves == 0
        assert sl.stats.elastic_patches >= 1 and sm.stats.elastic_patches >= 1
        assert ul[-1] == 0, ul   # coverage restored after the patch
        print("MULTIROUND_PARITY_OK")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=540, env=env,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "MULTIROUND_PARITY_OK" in out.stdout


# ------------------------------------------------ bench: re-solve counters


def test_bench_scenarios_reports_zero_host_solves():
    """Acceptance hook: the compiled-step path must show host_solves=0 on the
    emitted rows even though every round's pattern starts unseen."""
    sys.path.insert(0, _REPO)
    try:
        from benchmarks import common
        from benchmarks.bench_scenarios import run as bench_run

        mark = len(common.ROWS)
        bench_run(n=120, s=6, k=3, rounds=3, executors=("local",))
        rows = common.ROWS[mark:]
    finally:
        sys.path.pop(0)
    def field(derived, key):
        return int(derived.split(key + "=")[1].split()[0])

    cells = [r for r in rows if "host_solves=" in r[2]]
    assert len(cells) == 20  # 5 schemes × 4 scenarios
    for name, _us, derived in cells:
        assert field(derived, "host_solves") == 0, (name, derived)
        assert field(derived, "device_solves") > 0, (name, derived)
    assert any(field(d, "patches") > 0 for _n, _u, d in cells), (
        "sweep never exercised an elastic patch"
    )

# ------------------------------------- randomized recovery-parity oracle


def _recovered_gradient(b_full, A, shard_grads):
    """Lemma 3 on gradients in linear-algebra form: node i's local gradient
    is Σ_{s∈P_i} g_s; the combine is Σ_i b_i·(A g)_i = Σ_s (bᵀA)_s g_s."""
    per_node = A.astype(np.float64) @ shard_grads  # (s, d)
    return np.asarray(b_full, np.float64) @ per_node


def test_recovery_parity_oracle_fuzzed_patterns():
    """Seeded fuzz over straggler patterns: host-LP vs on-device-PGD
    recovered gradients pinned at 1e-5 wherever the exact band is achievable
    (FR always; cyclic for any ℓ−1 stragglers — δ* = 0 patterns), and
    band-bounded for Bernoulli (where the LP optimum is non-unique, so the
    two solvers legitimately pick different points of the feasible set)."""
    from repro.core import (
        bernoulli_assignment,
        cyclic_assignment,
        fixed_count_stragglers,
        fractional_repetition_assignment,
    )
    from repro.core.recovery import jax_recovery_masked, lp_recovery

    rng = np.random.default_rng(0)
    d = 5
    cases = [
        ("fr", fractional_repetition_assignment(24, 8, 2), 1, True),
        ("fr", fractional_repetition_assignment(24, 8, 2), 3, True),  # per-group deaths
        ("cyclic", cyclic_assignment(24, 8, 2), 1, True),
        ("cyclic", cyclic_assignment(24, 8, 3), 2, False),  # δ* > 0: band only
        ("bernoulli", bernoulli_assignment(24, 8, ell=4.0, rng=rng), 1, False),
    ]
    exact_checked = 0
    for name, a, t, exact in cases:
        A = a.matrix
        shard_grads = rng.normal(size=(a.num_shards, d))
        truth = shard_grads.sum(axis=0)
        for seed in range(6):
            alive = fixed_count_stragglers(a.num_nodes, t, np.random.default_rng(seed))
            if (A[alive].sum(axis=0) == 0).any():
                continue  # degenerate patterns exercised separately below
            lp = lp_recovery(a, alive)
            assert lp.feasible
            b_dev = np.asarray(
                jax_recovery_masked(A.astype(np.float32), alive, iters=1200)
            )
            assert (b_dev[~alive] == 0).all(), "stragglers must get zero weight"
            g_host = _recovered_gradient(lp.b_full, A, shard_grads)
            g_dev = _recovered_gradient(b_dev, A, shard_grads)
            scale = np.abs(truth).max()
            if exact:
                # δ* = 0 band is a point: both solvers must land on it.
                np.testing.assert_allclose(g_dev, g_host, atol=1e-5 * scale)
                np.testing.assert_allclose(g_dev, truth, atol=1e-5 * scale)
                exact_checked += 1
            else:
                # Non-unique optimum: pin each solver to ITS achieved band —
                # |recovered − truth| ≤ δ_achieved · Σ_s |g_s| coordinatewise.
                gmass = np.abs(shard_grads).sum(axis=0)
                for b in (lp.b_full, b_dev):
                    ach = np.asarray(b, np.float64) @ A
                    assert ach.min() >= 1.0 - 1e-3
                    bound = (ach.max() - 1.0) * gmass + 1e-4 * scale
                    assert (np.abs(_recovered_gradient(b, A, shard_grads) - truth) <= bound).all()
    assert exact_checked >= 10  # the 1e-5 pins actually ran


def test_recovery_parity_oracle_cost_path():
    """The same oracle through the REAL paths: `session.step_cost` (PGD
    inside the compiled step) vs the host-LP `resilient_cost` — 1e-5 on FR
    (δ = 0), for several fuzzed coverage-preserving patterns."""
    import jax
    import jax.numpy as jnp

    from repro.core import (
        ResilienceSession,
        fractional_repetition_assignment,
        lloyd,
        resilient_cost,
    )

    pts = _pts(120, seed=11)
    a = fractional_repetition_assignment(120, 6, 2)
    centers = np.asarray(
        lloyd(jax.random.PRNGKey(2), jnp.asarray(pts), 3, iters=4).centers
    )
    sess = ResilienceSession(a)
    for seed in range(4):
        rng = np.random.default_rng(seed)
        alive = np.ones(6, dtype=bool)
        alive[rng.integers(0, 6)] = False
        if (a.matrix[alive].sum(axis=0) == 0).any():
            continue
        dev = sess.step_cost(pts, centers, alive)
        host = float(resilient_cost(pts, centers, a, alive, recovery_method="lp"))
        assert dev == pytest.approx(host, rel=1e-5), (seed, dev, host)
    assert sess.stats.host_solves == 0  # the fused path never host-solved


def test_step_weights_degenerate_pattern_falls_back_to_host():
    """Uncovered-shard patterns must fall back to the host solver's
    best-effort weights — covered shards keep their full mass; the device
    solver (which masks lost shards out of its objective) is not consulted."""
    from repro.train.resilient import make_plan

    plan = make_plan(6, 6, redundancy=1, scheme="singleton")
    alive = np.array([True, True, False, True, True, True])  # shard 2 lost
    sess = plan.session
    before = sess.stats.device_solves
    w = plan.step_weights(alive)
    assert sess.stats.device_solves == before, "device solver must be skipped"
    assert sess.stats.host_solves == 1
    a_ach = w.astype(np.float64) @ plan.current_assignment.matrix
    covered = plan.current_assignment.matrix[alive].sum(axis=0) > 0
    np.testing.assert_allclose(a_ach[covered], 1.0, atol=1e-7)  # mass preserved
    assert (a_ach[~covered] == 0).all()  # lost shard reported, not faked
    # Coverage-preserving patterns use the device path (no new host solves).
    plan2 = make_plan(6, 6, redundancy=2, scheme="fr")
    w2 = plan2.step_weights(np.array([True, False, True, True, True, True]))
    assert plan2.session.stats.host_solves == 0
    assert plan2.session.stats.device_solves == 1
    np.testing.assert_allclose(
        w2.astype(np.float64) @ plan2.current_assignment.matrix, 1.0, atol=1e-4
    )


def test_step_weights_follow_elastic_patch():
    """After the session patches the assignment, plan.step_weights must
    solve against the PATCHED matrix (the pattern that lost coverage before
    the patch becomes device-solvable after it)."""
    from repro.core import ElasticPolicy, ResilienceSession
    from repro.core.assignment import cyclic_assignment
    from repro.train.resilient import RedundantShardPlan

    a = cyclic_assignment(8, 8, 2)
    plan = RedundantShardPlan(
        assignment=a, num_groups=8,
        session=ResilienceSession(a, elastic=ElasticPolicy(enabled=True, patience=2)),
    )
    dead = np.ones(8, dtype=bool)
    dead[[6, 7]] = False  # adjacent cyclic nodes: shard coverage lost
    w0 = plan.step_weights(dead)  # host fallback (uncovered)
    assert plan.session.stats.host_solves == 1
    for _ in range(3):
        plan.session.observe(dead)
    assert plan.session.stats.elastic_patches >= 1
    assert plan.current_assignment is not plan.assignment
    w1 = plan.step_weights(dead)  # patched matrix covers everything → device
    assert plan.session.stats.device_solves == 1
    A_cur = plan.current_assignment.matrix
    assert not (A_cur[dead].sum(axis=0) == 0).any()
    np.testing.assert_allclose(w1.astype(np.float64) @ A_cur, 1.0, atol=1e-3)
    assert w1.shape == w0.shape == (8,)


# ----------------------------------------- satellite: shards_per_group guard


def test_shards_per_group_raises_on_unbalanced():
    """Regression: shards_per_group used to report loads[0] as if uniform —
    on an unbalanced assignment that mis-sizes every consumer.  It must
    raise a clear ValueError instead (max_load/group_load serve unbalanced
    plans)."""
    from repro.core.assignment import Assignment
    from repro.train.resilient import RedundantShardPlan, make_plan

    mat = np.zeros((3, 6), dtype=np.uint8)
    mat[0, :4] = 1   # load 4
    mat[1, 3:] = 1   # load 3
    mat[2, [0, 5]] = 1  # load 2
    plan = RedundantShardPlan(
        assignment=Assignment(matrix=mat, scheme="crafted", params={}),
        num_groups=3,
    )
    with pytest.raises(ValueError, match="load-balanced"):
        _ = plan.shards_per_group
    assert plan.max_load == 4
    assert [plan.group_load(g) for g in range(3)] == [4, 3, 2]
    # Balanced constructions keep the uniform answer.
    assert make_plan(4, 8, redundancy=2, scheme="cyclic").shards_per_group == 4


def test_elastic_reshard_plan_survives_unbalanced_loads():
    """The group-manager's takeover path produces unbalanced plans on
    purpose; plan construction must accept them (only shards_per_group
    raises) and the data pipeline keeps its construction-time shapes."""
    from repro.data.pipeline import RedundantDataPipeline
    from repro.train.elastic import ElasticGroupManager
    from repro.train.resilient import make_plan

    plan = make_plan(4, 8, redundancy=2, scheme="cyclic")
    pipe = RedundantDataPipeline(plan, vocab=64, microbatch=1, seq_len=8)
    shape_before = pipe.batch_shape
    mgr = ElasticGroupManager(plan)
    mgr.mark_dead(0)
    mgr.mark_dead(1)  # adjacent deaths → coverage lost → reshard
    assert mgr.reshard_count >= 1
    with pytest.raises(ValueError, match="load-balanced"):
        _ = mgr.plan.shards_per_group
    assert mgr.plan.max_load >= 2
    assert pipe.batch_shape == shape_before  # static shapes snapshotted


def test_session_owns_permanent_loss_and_reshard():
    """The permanent-loss/reshard machinery lives in ResilienceSession (the
    group manager is a facade): covered losses re-solve once, coverage loss
    reshards, listeners fire, and every pattern cache is dropped."""
    from repro.core.assignment import cyclic_assignment
    from repro.core.resilience import ResilienceSession

    sess = ResilienceSession(cyclic_assignment(8, 4, 2))
    events = []
    sess.add_patch_listener(lambda moved, om, nm: events.append((tuple(moved), om, nm)))

    res = sess.permanent_loss(3)
    assert sess.stats.reshards == 0 and len(res.uncovered) == 0
    assert sess.permanent_dead == {3}
    assert not sess.alive_mask()[3] and sess.alive_mask()[0]

    res2 = sess.permanent_loss(2)  # adjacent deaths → coverage lost
    assert sess.stats.reshards == 1
    assert len(res2.uncovered) == 0  # survivors cover everything again
    assert sess.assignment.scheme == "elastic_cyclic"
    assert events and len(events[0][0]) > 0  # listener saw the changed rows
    assert sess.version == 1
    # Dead rows hold nothing; survivors hold all 8 shards.
    m = sess.assignment.matrix
    assert m[2].sum() == 0 and m[3].sum() == 0
    assert (m[[0, 1]].sum(axis=0) > 0).all()
    assert sess.pattern_covers(sess.alive_mask())

    sess.permanent_join(3)  # warm takeover: no reshard on joins
    assert sess.permanent_dead == {2} and sess.stats.reshards == 1


# --------------------------------------- scenario-matrix conformance test


_SCENARIO_MATRIX = ("iid", "fixed", "adversarial", "deadline", "trace")


@pytest.mark.parametrize("kind", _SCENARIO_MATRIX)
def test_scenario_matrix_reset_replay_conformance(kind, tmp_path):
    """Every make_scenario kind obeys the iterator contract uniformly:
    deterministic given its construction args, reset() replays the exact
    stream (masks AND step indices), records own their masks, and mask
    shapes match the node count."""
    from repro.core import cyclic_assignment, make_scenario, record_trace

    s = 6
    kw = {}
    if kind in ("iid", "fixed", "deadline"):
        kw["seed"] = 5
    if kind == "iid":
        kw["p_straggler"] = 0.3
    if kind == "fixed":
        kw["t"] = 2
    if kind == "adversarial":
        kw["assignment"] = cyclic_assignment(24, s, 2)
        kw["t"] = 1
    if kind == "trace":
        path = str(tmp_path / "conformance.jsonl")
        src = make_scenario("deadline", s, seed=9, p_spike=0.4)
        record_trace(src, 7, path)
        kw["path"] = path

    scen = make_scenario(kind, s, **kw)
    twin = make_scenario(kind, s, **kw)
    first = [next(scen) for _ in range(7)]
    for i, rec in enumerate(first):
        assert rec.alive.shape == (s,) and rec.alive.dtype == bool
        assert rec.index == i
    # Same construction args → identical stream (cross-instance determinism).
    for r1, r2 in zip(first, [next(twin) for _ in range(7)]):
        np.testing.assert_array_equal(r1.alive, r2.alive)
        np.testing.assert_allclose(r1.latencies, r2.latencies)
    # Records own their masks: corrupting one must not perturb the stream.
    first[3].alive[:] = ~first[3].alive
    scen.reset()
    again = [next(scen) for _ in range(7)]
    for i, (r1, r2) in enumerate(zip(first, again)):
        if i == 3:
            np.testing.assert_array_equal(~r1.alive, r2.alive)
        else:
            np.testing.assert_array_equal(r1.alive, r2.alive)
        assert r1.index == r2.index


def test_scenario_trace_roundtrip_equality(tmp_path):
    """record_trace → make_scenario("trace") reproduces EVERY source kind's
    mask stream exactly (the conformance matrix's round-trip leg)."""
    from repro.core import cyclic_assignment, make_scenario, record_trace

    s = 5
    sources = {
        "iid": {"p_straggler": 0.25, "seed": 3},
        "fixed": {"t": 1, "seed": 3},
        "adversarial": {"assignment": cyclic_assignment(20, s, 2), "t": 2},
        "deadline": {"seed": 3, "p_spike": 0.3},
    }
    for name, kw in sources.items():
        path = str(tmp_path / f"{name}.jsonl")
        src = make_scenario(name, s, **kw)
        assert record_trace(src, 5, path) == 5
        src.reset()
        replay = make_scenario("trace", s, path=path)
        for _ in range(5):
            want, got = next(src), next(replay)
            np.testing.assert_array_equal(got.alive, want.alive, err_msg=name)
            if want.latencies.size:
                np.testing.assert_allclose(got.latencies, want.latencies)
