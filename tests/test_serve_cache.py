"""Assignment-result cache correctness.

Unit tests for :class:`AssignmentCache` (LRU mechanics, quantized keys,
eager invalidation) plus the frontend-integrated behaviours the ISSUE pins:
hit on repeat query, miss + invalidate on ingest and on model-version bump,
and a randomized property test that a cached answer can never violate a
per-query staleness bound (generation-keyed entries always report exactly
the live staleness).
"""

import numpy as np
import pytest

from repro.serve import AdmissionError, AssignmentCache, ServingFrontend, VirtualClock
from repro.stream import StreamingSession
from repro.stream.query import QueryResult

D, K = 3, 3


def make_session(seed=0):
    rng = np.random.default_rng(seed)
    s = StreamingSession(d=D, k=K, num_nodes=4, leaf_size=64, seed=seed)
    s.ingest(rng.normal(size=(200, D)).astype(np.float32))
    s.solve()
    return s


def make_frontend(session, **kw):
    clk = VirtualClock()
    fe = ServingFrontend(
        window=0.001, max_batch=64, cache_size=kw.pop("cache_size", 128),
        clock=clk, **kw,
    )
    fe.add_tenant("a", session)
    return fe, clk


def _answer(fe, clk, q, **bounds):
    t = fe.submit("a", q, **bounds)
    if not t.done:
        clk.advance(fe.batcher.window)
        fe.flush()
    assert t.state == "done"
    return t


# ----------------------------------------------------------------- unit


def _res(i):
    return QueryResult(
        np.array([i], np.int32), np.zeros((1,), np.float32), 0, 0, 1
    )


def test_lru_hit_miss_eviction():
    c = AssignmentCache(maxsize=2)
    q = np.ones((1, 4), np.float32)
    k1 = c.key("t", (1, 0), q)
    assert c.get(k1) is None and c.misses == 1
    c.put(k1, _res(1))
    assert c.get(k1).indices[0] == 1 and c.hits == 1
    k2 = c.key("t", (1, 0), 2 * q)
    k3 = c.key("t", (1, 0), 3 * q)
    c.put(k2, _res(2))   # k2 now newer than k1's last touch
    c.put(k3, _res(3))   # capacity 2 → k1 (least recently touched) evicted
    assert c.evictions == 1
    assert c.get(k2) is not None and c.get(k1) is None
    assert 0.0 < c.hit_rate < 1.0


def test_quantized_keys_match_near_duplicates_only():
    c = AssignmentCache(maxsize=8, quantize=6)
    q = np.array([[0.123456789, 1.0]], np.float32)
    jitter = q + 1e-9   # below the quantization step → same key
    other = q + 1e-3    # above → different key
    assert c.key("t", (1, 0), q) == c.key("t", (1, 0), jitter)
    assert c.key("t", (1, 0), q) != c.key("t", (1, 0), other)


def test_generation_and_tenant_partition_the_key_space():
    c = AssignmentCache(maxsize=8)
    q = np.ones((2, 3), np.float32)
    assert c.key("a", (1, 0), q) != c.key("b", (1, 0), q)
    assert c.key("a", (1, 0), q) != c.key("a", (1, 1), q)  # ingest bump
    assert c.key("a", (1, 0), q) != c.key("a", (2, 0), q)  # version bump
    assert c.key("a", (1, 0), q) != c.key("a", (1, 0), q.reshape(3, 2))


def test_invalidate_is_eager_and_generation_scoped():
    c = AssignmentCache(maxsize=16)
    q = np.ones((1, 2), np.float32)
    for gen in [(1, 0), (1, 1), (2, 2)]:
        c.put(c.key("a", gen, q), _res(0))
    c.put(c.key("b", (1, 0), q), _res(9))
    assert c.invalidate("a", keep_generation=(2, 2)) == 2
    assert len(c) == 2  # a@(2,2) and b survive
    assert c.invalidate("a") == 1
    assert c.get(c.key("b", (1, 0), q)) is not None
    assert c.invalidations == 3


def test_zero_size_cache_never_stores():
    c = AssignmentCache(maxsize=0)
    k = c.key("t", (1, 0), np.ones((1, 2), np.float32))
    c.put(k, _res(1))
    assert c.get(k) is None and len(c) == 0


# ------------------------------------------------------------ integration


def test_hit_on_repeat_query():
    sess = make_session()
    fe, clk = make_frontend(sess)
    rng = np.random.default_rng(1)
    q = rng.normal(size=(4, D)).astype(np.float32)
    t1 = _answer(fe, clk, q)
    assert not t1.from_cache and fe.dispatches == 1
    t2 = _answer(fe, clk, q)
    # Answered at submit time from the cache: no second dispatch.
    assert t2.from_cache and fe.dispatches == 1
    np.testing.assert_array_equal(t2.result.indices, t1.result.indices)
    np.testing.assert_array_equal(t2.result.distances, t1.result.distances)
    assert fe.cache.hits == 1


def test_near_duplicate_query_hits():
    sess = make_session()
    fe, clk = make_frontend(sess)
    rng = np.random.default_rng(2)
    q = rng.normal(size=(2, D)).astype(np.float32)
    _answer(fe, clk, q)
    t = _answer(fe, clk, q + 1e-8)  # float jitter under the quantization step
    assert t.from_cache


def test_miss_and_invalidate_on_ingest():
    sess = make_session()
    fe, clk = make_frontend(sess)
    rng = np.random.default_rng(3)
    q = rng.normal(size=(3, D)).astype(np.float32)
    t1 = _answer(fe, clk, q)
    sess.ingest(rng.normal(size=(30, D)))  # generation bump: (v, i) → (v, i+1)
    t2 = _answer(fe, clk, q)
    assert not t2.from_cache and fe.dispatches == 2
    # The fresh answer carries the fresh staleness, not the cached one's.
    assert t1.result.staleness_points == 0
    assert t2.result.staleness_points == 30


def test_miss_and_invalidate_on_version_bump():
    sess = make_session()
    fe, clk = make_frontend(sess)
    rng = np.random.default_rng(4)
    q = rng.normal(size=(3, D)).astype(np.float32)
    t1 = _answer(fe, clk, q)
    sess.ingest(rng.normal(size=(100, D)))
    sess.solve()  # version bump; staleness resets to 0
    t2 = _answer(fe, clk, q)
    assert not t2.from_cache
    assert t2.result.version == t1.result.version + 1
    assert t2.result.staleness_points == 0


def test_cached_hit_still_subject_to_admission():
    sess = make_session()
    fe, clk = make_frontend(sess)
    rng = np.random.default_rng(5)
    q = rng.normal(size=(2, D)).astype(np.float32)
    sess.ingest(rng.normal(size=(20, D)))
    _answer(fe, clk, q)  # cached at staleness 20
    # A repeat of the same query with a violated bound must be REJECTED, not
    # served from the (bound-violating) cache entry.
    with pytest.raises(AdmissionError):
        fe.submit("a", q, max_staleness_points=10)
    # With a satisfiable bound the cached answer is served.
    t = fe.submit("a", q, max_staleness_points=20)
    assert t.from_cache


# --------------------------------------------------------------- property


def test_property_cached_answers_never_violate_staleness_bounds():
    """Randomized ingest/solve/query schedule: every answer — cached or
    fresh — must (a) satisfy the bound it was admitted under and (b) agree
    with a trusted direct computation at serve time."""
    rng = np.random.default_rng(42)
    sess = make_session(seed=7)
    fe, clk = make_frontend(sess, cache_size=64)
    pool = [rng.normal(size=(m, D)).astype(np.float32) for m in (1, 2, 3)]
    served = rejected = hits = 0
    for step in range(120):
        act = rng.random()
        if act < 0.25:
            sess.ingest(rng.normal(size=(int(rng.integers(1, 40)), D)))
        elif act < 0.35:
            sess.solve()
        else:
            q = pool[int(rng.integers(len(pool)))]
            bound = int(rng.integers(0, 120)) if rng.random() < 0.5 else None
            live = sess.staleness["points"]
            try:
                t = fe.submit("a", q, max_staleness_points=bound)
            except AdmissionError:
                rejected += 1
                assert bound is not None and live > bound
                continue
            if not t.done:
                clk.advance(fe.batcher.window)
                fe.flush()
            assert t.state == "done"
            served += 1
            hits += t.from_cache
            # (a) the bound held at serve time;
            if bound is not None:
                assert t.result.staleness_points <= bound
            # (b) the answer equals the trusted synchronous path, and its
            # reported staleness is the live one (generation-keyed entries
            # cannot resurface an older generation's answer or bound).
            ref = sess.query(q)
            np.testing.assert_array_equal(t.result.indices, ref.indices)
            assert t.result.staleness_points == ref.staleness_points
            assert t.result.version == ref.version
    assert served > 30 and hits > 5 and rejected > 0  # schedule hit all paths
