"""Property tests on the recurrent cells and robust aggregation.

These pin down the numerical invariants the dry-run cells rely on:
chunk-size invariance of the chunkwise mLSTM, associative-scan vs sequential
equivalence of RG-LRU, and the byzantine robustness of median-of-means
(paper §5 future-work direction, implemented as an optional aggregator)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import mom_combine, resilient_sum
from repro.models import xlstm as X
from repro.models import rglru as G
from tests.test_models_smoke import smoke_cfg


# ------------------------------------------------------------------ mLSTM


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_mlstm_chunk_size_invariance(chunk):
    """The chunkwise cell must give the same answer for every chunk size —
    the chunking is purely a compute schedule."""
    rng = np.random.default_rng(0)
    B, H, T, dh = 2, 2, 32, 8
    q = jnp.asarray(rng.normal(size=(B, H, T, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, T, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, T, dh)), jnp.float32)
    li = jnp.asarray(rng.normal(size=(B, H, T)), jnp.float32)
    lf = jnp.asarray(rng.normal(size=(B, H, T)) - 1.0, jnp.float32)
    ref = X._mlstm_chunkwise(q, k, v, li, lf, chunk=T)  # single chunk = exact parallel form
    got = X._mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_mlstm_chunkwise_matches_stepwise_recurrence():
    """Chunkwise (train) vs the pure sequential recurrence (decode form)."""
    rng = np.random.default_rng(1)
    B, H, T, dh = 1, 2, 24, 4
    q = jnp.asarray(rng.normal(size=(B, H, T, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, T, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, T, dh)), jnp.float32)
    li = jnp.asarray(rng.normal(size=(B, H, T)), jnp.float32)
    lf = jnp.asarray(rng.normal(size=(B, H, T)) - 1.0, jnp.float32)
    par = X._mlstm_chunkwise(q, k, v, li, lf, chunk=8)
    # Sequential stabilized recurrence.
    scale = dh**-0.5
    C = np.zeros((B, H, dh, dh))
    n = np.zeros((B, H, dh))
    m = np.zeros((B, H))
    outs = []
    for t in range(T):
        m_new = np.maximum(np.asarray(lf[:, :, t]) + m, np.asarray(li[:, :, t]))
        decay = np.exp(np.asarray(lf[:, :, t]) + m - m_new)
        inject = np.exp(np.asarray(li[:, :, t]) - m_new)
        kt = np.asarray(k[:, :, t])
        vt = np.asarray(v[:, :, t])
        C = decay[..., None, None] * C + inject[..., None, None] * (
            kt[..., :, None] * vt[..., None, :]
        )
        n = decay[..., None] * n + inject[..., None] * kt
        qt = np.asarray(q[:, :, t]) * scale
        num = np.einsum("bhd,bhde->bhe", qt, C)
        den = np.einsum("bhd,bhd->bh", qt, n)
        h = num / np.maximum(np.abs(den), np.exp(-m_new))[..., None]
        outs.append(h)
        m = m_new
    seq = np.stack(outs, axis=2)  # (B, H, T, dh)
    np.testing.assert_allclose(np.asarray(par), seq, rtol=5e-4, atol=5e-4)


# ------------------------------------------------------------------ RG-LRU


def test_rglru_associative_scan_matches_sequential():
    rng = np.random.default_rng(2)
    B, T, d = 2, 40, 8
    a = jnp.asarray(rng.uniform(0.7, 0.99, size=(B, T, d)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h_par = jax.lax.associative_scan(combine, (a, u), axis=1)
    h = np.zeros((B, d))
    outs = []
    for t in range(T):
        h = np.asarray(a[:, t]) * h + np.asarray(u[:, t])
        outs.append(h.copy())
    np.testing.assert_allclose(
        np.asarray(h_par), np.stack(outs, axis=1), rtol=2e-5, atol=2e-5
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_rglru_gate_bounds_property(seed):
    """RG-LRU decay a_t ∈ (0, 1): the recurrence is a strict contraction, so
    the hidden state stays bounded by max|u|/(1−max a) — no blowups at 500k
    steps (the long_500k cell's stability argument)."""
    rng = np.random.default_rng(seed)
    cfg = smoke_cfg("recurrentgemma-9b")
    p = G.rglru_init(jax.random.PRNGKey(seed), cfg)
    x = jnp.asarray(rng.normal(size=(1, 16, cfg.d_rnn or cfg.d_model)), jnp.float32)
    a, u = G._gates(p, x[:, :, : cfg.d_rnn or cfg.d_model].astype(jnp.float32), cfg)
    assert float(a.min()) > 0.0 and float(a.max()) < 1.0
    assert np.isfinite(np.asarray(u)).all()


# ---------------------------------------------------------------- byzantine


def test_mom_combine_resists_corrupted_nodes():
    """Median-of-means (paper §5): a single byzantine node sending 1e6-scale
    garbage corrupts the Lemma-3 weighted sum but not the MoM combine."""
    rng = np.random.default_rng(3)
    s, dim = 10, 6
    true = rng.normal(size=(dim,))
    stats = np.stack([true + 0.01 * rng.normal(size=dim) for _ in range(s)])
    corrupted = stats.copy()
    corrupted[3] = 1e6
    b = np.ones(s)
    naive = np.asarray(resilient_sum(jnp.asarray(corrupted), b)) / s
    robust = np.asarray(mom_combine(jnp.asarray(corrupted), num_groups=5)) / s
    assert np.abs(naive - true).max() > 1e3  # naive combine destroyed
    assert np.abs(robust - true).max() < 1.0  # MoM survives


def test_mom_combine_unbiased_without_corruption():
    rng = np.random.default_rng(4)
    stats = jnp.asarray(rng.normal(loc=2.0, size=(20, 5)), jnp.float32)
    out = np.asarray(mom_combine(stats, num_groups=4)) / 20
    np.testing.assert_allclose(out, np.asarray(stats).mean(0), rtol=0.3, atol=0.3)
