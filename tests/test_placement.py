"""Health-aware placement optimizer tests (repro.core.placement) and the
satellite fixes that feed it: (health, load)-ordered elastic repair targets,
node_straggle_ewma gauge lifecycle across permanent_loss/permanent_join, and
bernoulli degenerate-draw hardening.
"""

import json

import numpy as np
import pytest

from repro.core import (
    ElasticPolicy,
    PlacementOptimizer,
    ResilienceSession,
    choose_ell,
    cyclic_assignment,
    expected_completion_time,
    health_assignment,
    make_assignment,
    round_miss_probability,
)
from repro.core.assignment import (
    Assignment,
    bernoulli_assignment,
    node_loads,
    shard_replication,
)
from repro.core.stragglers import TraceScenario
from repro.obs import default_registry


# ---------------------------------------------------------------- cost model


def test_expected_completion_time_model():
    a = cyclic_assignment(12, 4, 2)
    # All healthy: ECT is the all-alive makespan (perfectly balanced loads).
    assert expected_completion_time(a, np.zeros(4)) == pytest.approx(6.0)
    # Chronic stragglers co-holding shards inflate the retry term.
    q = np.array([0.0, 0.0, 0.9, 0.9])
    assert expected_completion_time(a, q) > 6.0
    # Faster nodes finish their shards sooner: doubling every capacity
    # halves the ECT.
    cap = np.full(4, 2.0)
    assert expected_completion_time(a, np.zeros(4), cap) == pytest.approx(3.0)


def test_unplaced_shard_is_a_certain_miss_not_a_silent_zero():
    m = cyclic_assignment(4, 4, 1).matrix.copy()
    m[:, 0] = 0
    bad = Assignment(matrix=m, scheme="cyclic", params={})
    assert round_miss_probability(bad.matrix, np.zeros(4)) == 1.0
    assert np.isinf(expected_completion_time(bad, np.zeros(4)))


# ------------------------------------------------------------- construction


def test_health_assignment_avoids_chronic_stragglers_and_beats_uniform():
    q = np.array([0.02, 0.03, 0.01, 0.02, 0.05, 0.03, 0.95, 0.9])
    a = make_assignment("health", 64, 8, ell=2, health=q)
    assert a.scheme == "health"
    assert (shard_replication(a) == 2).all()
    # Every shard keeps a replica on a healthy node (hard constraint) and
    # the chronic stragglers carry far less than the healthy nodes.
    healthy = q < 0.5
    assert (a.matrix[healthy].sum(axis=0) >= 1).all()
    loads = node_loads(a)
    assert loads[6] + loads[7] < loads[healthy].min()
    # Never worse than the uniform constructions under the same model —
    # they are in the candidate pool.
    for uniform in ("cyclic", "fr"):
        u = make_assignment(uniform, 64, 8, ell=2)
        assert expected_completion_time(a, q) <= expected_completion_time(u, q)


def test_choose_ell_scales_with_risk():
    assert choose_ell(16, 8, np.zeros(8)) == 1
    assert choose_ell(16, 8, np.full(8, 0.05)) == 2
    # High uniform risk saturates at the cap rather than looping forever.
    assert choose_ell(16, 8, np.full(8, 0.3), max_ell=4) == 4
    a = make_assignment("health", 16, 8, ell=None, health=np.full(8, 0.05))
    assert a.params["ell"] == 2


def test_optimizer_excludes_dead_nodes_hard():
    q = np.full(8, 0.05)
    exclude = np.zeros(8, dtype=bool)
    exclude[[2, 6]] = True
    a = PlacementOptimizer(ell=2).optimize(40, 8, q, exclude=exclude)
    assert (a.matrix[exclude] == 0).all()
    assert (shard_replication(a) == 2).all()
    with pytest.raises(ValueError, match="allowed"):
        PlacementOptimizer().optimize(8, 4, np.zeros(4), exclude=np.ones(4, bool))


def test_correlation_groups_are_spanned():
    groups = np.array([0, 0, 1, 1])
    a = health_assignment(12, 4, health=np.zeros(4), ell=2, groups=groups)
    for j in range(12):
        holders = np.flatnonzero(a.matrix[:, j])
        assert np.unique(groups[holders]).size >= 2


def test_make_assignment_rejects_unknown_scheme_listing_health():
    with pytest.raises(ValueError, match="health"):
        make_assignment("nope", 8, 4)


# ---------------------------------------------- satellite: bernoulli audit


def test_bernoulli_seed_stability_including_cover_reroll():
    a1 = bernoulli_assignment(8, 6, ell=1.0, rng=np.random.default_rng(7))
    a2 = bernoulli_assignment(8, 6, ell=1.0, rng=np.random.default_rng(7))
    np.testing.assert_array_equal(a1.matrix, a2.matrix)
    # Tiny p forces empty columns, so the ensure_cover re-roll path runs —
    # it draws from the same generator and must be just as deterministic.
    b1 = bernoulli_assignment(16, 4, ell=0.2, rng=np.random.default_rng(3))
    b2 = bernoulli_assignment(16, 4, ell=0.2, rng=np.random.default_rng(3))
    np.testing.assert_array_equal(b1.matrix, b2.matrix)
    assert (shard_replication(b1) >= 1).all()


def test_bernoulli_zero_row_is_inert_everywhere():
    """A node that draws no shards (all-zero ROW — legal, unlike an all-zero
    column) must flow through load accounting, shard packing, and the
    placement cost model without crashing or skewing anything."""
    from repro.core.kmedian import pack_local_shards

    a = None
    for seed in range(100):
        cand = bernoulli_assignment(4, 8, ell=1.0, rng=np.random.default_rng(seed))
        if (node_loads(cand) == 0).any():
            a = cand
            break
    assert a is not None, "no zero-load draw in 100 seeds — p=(7/8)^4 per row"
    loads = node_loads(a)
    assert loads.sum() == int(a.matrix.sum())
    pts = np.random.default_rng(0).normal(size=(4, 3)).astype(np.float32)
    xs, ws = pack_local_shards(pts, a)
    assert xs.shape[0] == 8
    assert (ws[loads == 0] == 0).all()  # empty nodes pack as weight-0 padding
    q = np.full(8, 0.2)
    ect = expected_completion_time(a, q)
    assert np.isfinite(ect) and ect > 0
    # A q=1 node must not divide-by-zero the greedy per-node score either.
    q[0] = 1.0
    h = health_assignment(4, 8, health=q, ell=2)
    assert (shard_replication(h) == 2).all()
    assert node_loads(h)[0] == 0  # and it receives nothing


# ------------------------------------------------- satellite: gauge lifecycle


def test_metrics_registry_remove():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    reg.gauge("g", labels={"node": "0"}).set(1.0)
    reg.gauge("g", labels={"node": "1"}).set(2.0)
    assert reg.remove("g", {"node": "0"})
    assert not reg.remove("g", {"node": "0"})  # already gone
    assert set(reg.collect()["g"]) == {(("node", "1"),)}
    assert reg.remove("g", {"node": "1"})
    assert "g" not in reg.families()  # empty family dropped
    assert not reg.remove("never_registered")


def _session_gauge_nodes(sess):
    fam = default_registry().collect().get("node_straggle_ewma", {})
    want = sess._obs_labels["session"]
    return {
        dict(k)["node"] for k in fam if dict(k).get("session") == want
    }


def test_node_health_and_gauges_track_live_node_set():
    sess = ResilienceSession(cyclic_assignment(12, 4, 2))
    for _ in range(3):
        sess.observe(np.ones(4, dtype=bool))
    assert _session_gauge_nodes(sess) == {"0", "1", "2", "3"}
    assert sess.node_health().shape == (4,)
    sess.permanent_loss(3)
    assert sess.node_health().shape == (3,)
    assert _session_gauge_nodes(sess) == {"0", "1", "2"}
    # Later rounds must not resurrect the dead node's gauge — even when the
    # scenario mask claims it is alive — nor decay its EWMA toward healthy.
    for _ in range(5):
        sess.observe(np.ones(4, dtype=bool))
    assert _session_gauge_nodes(sess) == {"0", "1", "2"}
    assert sess._straggle_ewma[3] == 1.0
    sess.permanent_join(3)
    assert sess.node_health().shape == (4,)
    assert sess.node_health()[3] == 0.0  # fresh machine, clean record
    assert _session_gauge_nodes(sess) == {"0", "1", "2", "3"}


# --------------------------------------- satellite: repair-target selection


def _pingpong_session(tmp_path, health_aware):
    """Nodes 0–3 steady; node 4 permanently flaky from round 8; node 5 is
    chronically flaky for 8 rounds, then briefly back exactly when the patch
    fires — high EWMA, zero streak, zero load: the legacy least-loaded pick
    targets it, the health-aware pick must not."""
    masks = (
        [[1, 1, 1, 1, 1, 0]] * 8
        + [[1, 1, 1, 1, 0, 1]] * 2
        + [[1, 1, 1, 1, 0, 0]] * 2
    )
    path = tmp_path / f"pingpong_{health_aware}.jsonl"
    path.write_text("\n".join(json.dumps({"alive": m}) for m in masks) + "\n")
    mat = np.zeros((6, 6), dtype=np.uint8)
    for j in range(6):
        mat[j % 5, j] = 1
        mat[(j + 1) % 5, j] = 1  # node 5 starts empty
    sess = ResilienceSession(
        Assignment(matrix=mat, scheme="cyclic", params={"ell": 2}),
        elastic=ElasticPolicy(patience=2, health_aware=health_aware),
    )
    events = [sess.observe(step) for step in TraceScenario(6, str(path), loop=False)]
    return sess, events


def test_health_aware_repair_converges_where_legacy_pingpongs(tmp_path):
    # Legacy least-loaded pick: patch #1 lands the at-risk shards on flaky
    # node 5 (it is empty), whose next persistent streak puts the SAME
    # shards back at risk — a second patch evacuates what the first placed.
    legacy, legacy_events = _pingpong_session(tmp_path, health_aware=False)
    legacy_moves = [e["moved_nodes"] for e in legacy_events if e["patched"]]
    assert legacy.stats.elastic_patches >= 2
    assert 5 in legacy_moves[0]
    # Health-aware (EWMA, load) pick: node 5's record disqualifies it, the
    # patch lands on genuinely reliable nodes, and no later round re-patches.
    fixed, fixed_events = _pingpong_session(tmp_path, health_aware=True)
    fixed_moves = [e["moved_nodes"] for e in fixed_events if e["patched"]]
    assert fixed.stats.elastic_patches == 1
    assert all(5 not in moved for moved in fixed_moves)
    # The at-risk shards ended with ≥ 2 replicas on the steady nodes.
    steady_cover = fixed.assignment.matrix[:4].sum(axis=0)
    assert (steady_cover[[3, 4]] >= 2).all()


# ------------------------------------------------ session lifecycle rewiring


def test_permanent_loss_reoptimizes_placement_and_join_restores(tmp_path):
    a = make_assignment("health", 24, 6, ell=2)
    sess = ResilienceSession(a, placement=PlacementOptimizer(ell=2))
    # Learn heterogeneous health online: node 5 flaky, the rest steady.
    flaky = np.ones(6, dtype=bool)
    flaky[5] = False
    for _ in range(6):
        sess.observe(flaky)
    # Seed the pattern cache, then lose node 0 for good.
    sess.recovery(np.ones(6, dtype=bool))
    invalidated_before = sess.stats.cache_invalidations
    res = sess.permanent_loss(0)
    assert res.feasible
    assert sess.stats.placement_reoptimizes == 1
    assert sess.stats.reshards == 0  # re-optimize, not the legacy reshard
    assert sess.version == 1
    assert sess.assignment.scheme == "health"
    assert (sess.assignment.matrix[0] == 0).all()
    assert (shard_replication(sess.assignment) >= 1).all()
    # Invalidation went through the selective path (counted per entry), and
    # the flaky survivor carries less than the steady ones.
    assert sess.stats.cache_invalidations > invalidated_before
    loads = node_loads(sess.assignment)
    assert loads[5] <= loads[1:5].min()
    # Rejoin: health record reset, placement re-optimized, node 0 used again.
    sess.permanent_join(0)
    assert sess.stats.placement_reoptimizes == 2
    assert node_loads(sess.assignment)[0] > 0
    assert sess.node_health().shape == (6,)


def test_legacy_reshard_folds_dead_rows_onto_healthiest_survivor():
    # fr with groups {0,1} and {2,3}: losing nodes 0 AND 2 breaks coverage
    # for the shards they co-held, forcing the legacy reshard path.
    sess = ResilienceSession(make_assignment("fr", 12, 4, ell=2))
    for _ in range(4):  # node 1 flaky (but alive when it matters)
        sess.observe(np.array([True, False, True, True]))
    sess.permanent_loss(0)
    assert sess.stats.reshards == 0  # still covered after one loss
    sess.permanent_loss(2)
    assert sess.stats.reshards == 1
    assert sess.assignment.scheme == "elastic_cyclic"
    loads = node_loads(sess.assignment)
    assert loads[0] == 0 and loads[2] == 0
    # Both dead rows folded onto node 3 (EWMA ≈ 0), never the flaky node 1 —
    # the blind row-rotation of the old takeover would have used node 1.
    assert loads[3] > loads[1]
