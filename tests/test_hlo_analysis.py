"""Unit tests for the loop-aware HLO cost analyzer (the §Roofline substrate)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    a = analyze_hlo(_hlo(lambda x, w: x @ w, x, w))
    assert a["flops"] == 2 * 64 * 32 * 16


def test_scan_multiplies_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scanned(x, w):
        def body(c, _):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    single = analyze_hlo(_hlo(lambda x, w: x @ w, x, w))["flops"]
    scanned_f = analyze_hlo(_hlo(scanned, x, w))["flops"]
    assert scanned_f == pytest.approx(8 * single, rel=1e-6)


def test_nested_scan_multiplies_product():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def nested(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, ()
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, ()
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    single = analyze_hlo(_hlo(lambda x, w: x @ w, x, w))["flops"]
    got = analyze_hlo(_hlo(nested, x, w))["flops"]
    assert got == pytest.approx(15 * single, rel=1e-6)


def test_bytes_positive_and_scale_with_size():
    small = analyze_hlo(
        _hlo(lambda x: jnp.tanh(x) * 2, jax.ShapeDtypeStruct((128,), jnp.float32))
    )["bytes"]
    big = analyze_hlo(
        _hlo(lambda x: jnp.tanh(x) * 2, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    )["bytes"]
    assert 0 < small < big


def test_dus_in_scan_costs_slice_not_buffer():
    """Stacked scan outputs must not be charged the full buffer per step."""
    x = jax.ShapeDtypeStruct((4, 256), jnp.float32)

    def stacking(x):
        def body(c, _):
            c = c * 1.5
            return c, c  # ys stacking → per-step DUS into (64, 4, 256)
        _, ys = jax.lax.scan(body, x, None, length=64)
        return ys

    a = analyze_hlo(_hlo(stacking, x))
    # Naive costing would be ≥ 2 × 64steps × full(64·4·256·4B) ≈ 33.5 MB;
    # slice-aware costing stays well under 10 MB.
    assert a["bytes"] < 1e7


def test_collectives_counted_with_loop_multiplier():
    import subprocess, sys, textwrap, os, json

    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo
        from repro.launch.compat import make_auto_mesh, shard_map
        mesh = make_auto_mesh((4,), ("d",))

        def f(x):
            def body(c, _):
                # psum of a reduced stat keeps the carry's vma type stable.
                return c + jax.lax.psum(jnp.sum(c), "d"), ()
            y, _ = jax.lax.scan(body, x, None, length=5)
            return y

        g = shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P("d"))
        hlo = jax.jit(g).lower(jax.ShapeDtypeStruct((8, 16), jnp.float32)).compile().as_text()
        a = analyze_hlo(hlo)
        print(json.dumps({"coll": a["collective_bytes"], "ops": a["collective_ops"]}))
        """
    )
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=300, env=env
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    # 5 loop iterations of a scalar psum: ≥ 5 × 4 B counted (loop-aware).
    assert rec["coll"] >= 5 * 4
    assert rec["ops"] >= 1
