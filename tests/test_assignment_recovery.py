"""Property and unit tests for the assignment schemes and recovery solvers."""

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st

from repro.core import (
    adversarial_stragglers,
    bernoulli_assignment,
    cyclic_assignment,
    fixed_count_stragglers,
    fractional_repetition_assignment,
    lp_recovery,
    min_cover_after_stragglers,
    node_loads,
    random_stragglers,
    satisfies_property1,
    shard_replication,
    singleton_assignment,
    solve_recovery,
    theorem6_ell,
    uniform_recovery,
)
from repro.core.recovery import jax_recovery


def test_theorem6_ell_monotonic():
    # Smaller delta and larger straggler probability both demand more replication.
    assert theorem6_ell(1000, 0.25, 0.1) > theorem6_ell(1000, 0.5, 0.1)
    assert theorem6_ell(1000, 0.5, 0.3) > theorem6_ell(1000, 0.5, 0.1)
    assert theorem6_ell(10_000, 0.5, 0.1) > theorem6_ell(100, 0.5, 0.1)


def test_bernoulli_shapes_and_cover():
    rng = np.random.default_rng(0)
    a = bernoulli_assignment(500, 20, ell=4.0, rng=rng)
    assert a.matrix.shape == (20, 500)
    assert shard_replication(a).min() >= 1  # ensure_cover
    assert a.params["p_a"] == pytest.approx(0.2)


def test_fractional_repetition_structure():
    a = fractional_repetition_assignment(120, 12, 3)
    # Every shard replicated exactly ell times; loads balanced within a group.
    assert (shard_replication(a) == 3).all()
    assert node_loads(a).sum() == 3 * 120


def test_fr_exact_recovery_under_adversary():
    a = fractional_repetition_assignment(100, 12, 4)
    alive = adversarial_stragglers(a, 3)  # ell-1 adversarial stragglers
    res = lp_recovery(a, alive)
    assert res.feasible and res.delta <= 1e-9  # exact: a ≡ 1
    assert len(res.uncovered) == 0


def test_cyclic_tolerates_ell_minus_1():
    a = cyclic_assignment(97, 10, 4)
    alive = adversarial_stragglers(a, 3)
    res = lp_recovery(a, alive)
    assert res.feasible
    assert len(res.uncovered) == 0


def test_singleton_loses_data_on_any_straggler():
    a = singleton_assignment(50, 10)
    alive = fixed_count_stragglers(10, 1, np.random.default_rng(0))
    assert min_cover_after_stragglers(a, alive) == 0
    res = lp_recovery(a, alive)
    assert len(res.uncovered) > 0  # information irrecoverably lost


def test_lp_recovery_band_is_minimal():
    # On an exactly-coverable instance, LP must find delta == 0.
    a = fractional_repetition_assignment(60, 8, 2)
    alive = np.ones(8, dtype=bool)
    res = lp_recovery(a, alive)
    assert res.feasible and res.delta <= 1e-9
    # And b must be supported only on alive nodes.
    assert res.b_full.shape == (8,)
    assert (res.b_full >= 0).all()


def test_uniform_recovery_matches_paper_form():
    rng = np.random.default_rng(1)
    n, s, p_t, delta = 2000, 50, 0.1, 0.5
    a = bernoulli_assignment(n, s, delta=delta, p_straggler=p_t, rng=rng)
    alive = random_stragglers(s, p_t, rng)
    res = uniform_recovery(a, alive)
    # All alive weights equal (the paper's closed form).
    nz = res.b[res.b > 0]
    assert np.allclose(nz, nz[0])
    # Theorem 6 regime: Property 1 should hold for this realization.
    assert res.feasible
    assert res.delta <= delta + 0.25  # slack: single realization, finite n


def test_recovery_result_coverage_fraction():
    a = singleton_assignment(30, 6)
    alive = np.array([True, True, True, False, False, True])
    res = lp_recovery(a, alive)
    assert 0.0 < res.covered_fraction < 1.0


def test_jax_recovery_agrees_with_lp():
    rng = np.random.default_rng(2)
    a = bernoulli_assignment(80, 12, ell=5.0, rng=rng)
    alive = fixed_count_stragglers(12, 2, rng)
    lp = lp_recovery(a, alive)
    b = np.asarray(jax_recovery(a.submatrix(alive), iters=800))
    achieved = b @ a.submatrix(alive)
    covered = a.submatrix(alive).sum(axis=0) > 0
    if lp.feasible:
        assert achieved[covered].min() >= 1.0 - 1e-4
        # Heuristic solver: band within a constant factor of the LP optimum
        # (PGD+rescale is not minimax; it trades band quality for being
        # jit-able on-device).
        assert achieved[covered].max() <= 4.0 * (1.0 + lp.delta)


def test_satisfies_property1_exhaustive_small():
    a = fractional_repetition_assignment(40, 6, 3)
    assert satisfies_property1(a, t=2, delta=1e-6)
    # Killing an entire replica set of 3 CAN lose a shard only if all three
    # replicas die; t=3 adversarial breaks FR with ell=3.
    assert not satisfies_property1(a, t=3, delta=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    s=st.integers(min_value=4, max_value=16),
    ell=st.integers(min_value=2, max_value=4),
    t=st.integers(min_value=0, max_value=2),
    n=st.integers(min_value=10, max_value=200),
)
def test_cyclic_property1_hypothesis(s, ell, t, n):
    """Cyclic assignment tolerates any t ≤ ell−1 stragglers with b ≥ 0."""
    if ell > s or t >= ell:
        return
    a = cyclic_assignment(n, s, ell)
    rng = np.random.default_rng(n * 31 + s)
    alive = fixed_count_stragglers(s, t, rng)
    res = lp_recovery(a, alive)
    assert res.feasible
    assert len(res.uncovered) == 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_lemma3_sandwich_property(seed):
    """Lemma 3: cost(P,C,w) ≤ Σ b_i cost(P_i,C,w) ≤ (1+δ)cost(P,C,w)
    for arbitrary centers and weights — checked on the achieved δ."""
    rng = np.random.default_rng(seed)
    n, s, d = 150, 8, 3
    pts = rng.normal(size=(n, d))
    w = rng.random(n) + 0.1
    a = bernoulli_assignment(n, s, ell=4.0, rng=rng)
    alive = fixed_count_stragglers(s, 2, rng)
    res = lp_recovery(a, alive)
    if not res.feasible:
        return
    C = rng.normal(size=(4, d))
    dists = np.sqrt(((pts[:, None, :] - C[None, :, :]) ** 2).sum(-1)).min(1)
    full = float((w * dists).sum())
    parts = sum(
        res.b_full[i] * float((w[a.shards_of(i)] * dists[a.shards_of(i)]).sum())
        for i in range(s)
        if res.b_full[i] > 0
    )
    assert full * (1 - 1e-6) <= parts <= (1 + res.delta) * full * (1 + 1e-6)
