"""Per-architecture smoke tests (deliverable f): reduced configs of the same
family, one forward/train step on CPU, asserting output shapes + no NaNs."""

import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.registry import get_config, list_archs

pytestmark = pytest.mark.slow  # model-zoo compile-heavy; run via `make test-all`

ARCH_MODULES = {
    "qwen3-4b": "qwen3_4b",
    "qwen3-8b": "qwen3_8b",
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen3-1.7b": "qwen3_1_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "xlstm-1.3b": "xlstm_1_3b",
    "internvl2-1b": "internvl2_1b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "musicgen-large": "musicgen_large",
}


def smoke_cfg(arch: str):
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.smoke_config().validate()


def make_batch(cfg, B=2, T=32, key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    batch = {}
    if cfg.num_codebooks > 0:
        batch["tokens"] = jax.random.randint(key, (B, cfg.num_codebooks, T), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(key, (B, T), 0, cfg.vocab)
    if cfg.num_prefix_tokens > 0:
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.num_prefix_tokens, cfg.d_model), jnp.float32
        )
    return batch


def test_all_ten_archs_registered():
    assert set(ARCH_MODULES) == set(list_archs())


@pytest.mark.parametrize("arch", sorted(ARCH_MODULES))
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
    assert got == expected
    assert len(cfg.block_types) == cfg.n_layers


@pytest.mark.parametrize("arch", sorted(ARCH_MODULES))
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_cfg(arch)
    ctx = T.ModelContext()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, aux, mask = T.forward_train(params, batch, cfg, ctx)
    B, Ttok = 2, 32
    total_T = Ttok + (cfg.num_prefix_tokens if cfg.num_prefix_tokens else 0)
    if cfg.num_codebooks > 0:
        assert logits.shape == (B, Ttok, cfg.num_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, total_T, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = T.loss_fn(params, batch, cfg, ctx)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: T.loss_fn(p, batch, cfg, ctx)[0])(params)
    gn = sum(
        float(jnp.sum(jnp.square(g.astype(jnp.float32))))
        for g in jax.tree_util.tree_leaves(grads)
    )
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", sorted(ARCH_MODULES))
def test_smoke_group_weighted_loss_matches_uniform(arch):
    """With b ≡ 1 (no stragglers, exact cover) the group-weighted loss equals
    the plain mean — Lemma 3's a ≡ 1 case on gradients' primal."""
    cfg = smoke_cfg(arch)
    ctx = T.ModelContext()
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    batch = make_batch(cfg)
    plain, _ = T.loss_fn(params, batch, cfg, ctx)
    weighted, _ = T.loss_fn(
        params, {**batch, "group_weights": jnp.ones((2,))}, cfg, ctx
    )
    np.testing.assert_allclose(float(plain), float(weighted), rtol=2e-5)


@pytest.mark.parametrize("arch", sorted(ARCH_MODULES))
def test_smoke_decode_step(arch):
    cfg = smoke_cfg(arch)
    ctx = T.ModelContext()
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    B, max_len = 2, 16
    cache = T.init_cache(cfg, B, max_len)
    if cfg.num_codebooks > 0:
        tok = jnp.zeros((B, cfg.num_codebooks, 1), jnp.int32)
    else:
        tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = T.decode_step(params, cache, tok, jnp.asarray(0, jnp.int32), cfg, ctx)
    if cfg.num_codebooks > 0:
        assert logits.shape == (B, 1, cfg.num_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # Cache must actually change for stateful blocks.
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        cache, cache2,
    )
    assert sum(jax.tree_util.tree_leaves(diff)) > 0


DENSE_ARCHS = ["qwen3-4b", "qwen2.5-3b", "musicgen-large"]


@pytest.mark.parametrize("arch", DENSE_ARCHS)
def test_decode_consistency_with_teacher_forcing(arch):
    """Token-by-token decode logits must match the parallel training forward
    (same params, same tokens) — the KV-cache path is exact for attention."""
    cfg = smoke_cfg(arch)
    ctx = T.ModelContext(attn_impl="ref")
    params = T.init_params(jax.random.PRNGKey(3), cfg)
    B, Ttok = 1, 8
    batch = make_batch(cfg, B=B, T=Ttok, key=jax.random.PRNGKey(4))
    full_logits, _, _ = T.forward_train(params, batch, cfg, ctx)
    cache = T.init_cache(cfg, B, Ttok)
    toks = batch["tokens"]
    outs = []
    for t in range(Ttok):
        tok_t = toks[..., t : t + 1]
        lg, cache = T.decode_step(params, cache, tok_t, jnp.asarray(t, jnp.int32), cfg, ctx)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)  # (B, T, [K,] V)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_xlstm_decode_consistency():
    """mLSTM chunkwise-parallel (train) vs recurrent step (decode) must agree
    — validates the stabilized chunkwise cell math end-to-end."""
    cfg = smoke_cfg("xlstm-1.3b")
    ctx = T.ModelContext()
    params = T.init_params(jax.random.PRNGKey(5), cfg)
    B, Ttok = 1, 12
    batch = make_batch(cfg, B=B, T=Ttok, key=jax.random.PRNGKey(6))
    full_logits, _, _ = T.forward_train(params, batch, cfg, ctx)
    cache = T.init_cache(cfg, B, Ttok)
    outs = []
    for t in range(Ttok):
        lg, cache = T.decode_step(
            params, cache, batch["tokens"][:, t : t + 1],
            jnp.asarray(t, jnp.int32), cfg, ctx,
        )
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_rglru_decode_consistency():
    """RG-LRU associative scan (train) vs per-token step (decode)."""
    cfg = smoke_cfg("recurrentgemma-9b")
    ctx = T.ModelContext(attn_impl="chunked")  # local attn needs window support
    params = T.init_params(jax.random.PRNGKey(7), cfg)
    B, Ttok = 1, 10
    batch = make_batch(cfg, B=B, T=Ttok, key=jax.random.PRNGKey(8))
    full_logits, _, _ = T.forward_train(params, batch, cfg, ctx)
    cache = T.init_cache(cfg, B, Ttok)
    outs = []
    for t in range(Ttok):
        lg, cache = T.decode_step(
            params, cache, batch["tokens"][:, t : t + 1],
            jnp.asarray(t, jnp.int32), cfg, ctx,
        )
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_moe_capacity_routing_mass():
    """Router mass reaching experts ≈ top-k probability mass (capacity 1.25
    drops little at uniform load); output is finite and shaped."""
    cfg = smoke_cfg("deepseek-moe-16b")
    from repro.models import moe as M

    params = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    out, aux = M.moe_apply(params, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out, np.float32)).all()
    assert float(aux) > 0.5  # aux ≈ 1 at uniform routing


def test_prefill_returns_last_position_logits_and_cache():
    cfg = smoke_cfg("qwen3-4b")
    ctx = T.ModelContext(attn_impl="ref")
    params = T.init_params(jax.random.PRNGKey(9), cfg)
    batch = make_batch(cfg, B=2, T=16)
    logits, cache = T.prefill(params, batch, cfg, ctx)
    assert logits.shape == (2, 1, cfg.vocab)
    k = cache["unit"]["slot0"]["k"]
    assert k.shape == (cfg.scan_repeats, 2, 16, cfg.n_kv_heads, cfg.head_dim)
