"""End-to-end tests for the paper's Algorithms 1–3 and the coreset layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st

from repro.core import (
    bernoulli_assignment,
    centralized_pca,
    clustering_cost,
    fixed_count_stragglers,
    fractional_repetition_assignment,
    ignore_stragglers_kmedian,
    lloyd,
    lloyd_subspace,
    pca_cost,
    relaxed_coreset_rank,
    resilient_kmedian,
    resilient_pca,
    resilient_subspace_clustering,
    sensitivity_coreset,
    singleton_assignment,
    subspace_cost,
    uniform_coreset,
)
from repro.data.synthetic import franti_s1_like, gaussian_mixture, planted_subspaces


@pytest.fixture(scope="module")
def s1():
    return franti_s1_like(1500)


def test_lloyd_kmeans_recovers_planted_clusters():
    pts, centers, _ = gaussian_mixture(800, 6, 4, spread=0.02, rng=np.random.default_rng(1))
    res = lloyd(jax.random.PRNGKey(0), jnp.asarray(pts), 6, iters=25)
    # Every found center is near a planted center.
    d = np.sqrt(((np.asarray(res.centers)[:, None] - centers[None]) ** 2).sum(-1)).min(1)
    assert (d < 0.15).all()
    assert np.isfinite(float(res.cost))


def test_lloyd_weighted_ignores_zero_weight_padding():
    pts, _, _ = gaussian_mixture(400, 4, 3, rng=np.random.default_rng(2))
    padded = np.concatenate([pts, np.full((100, 3), 1e6, np.float32)])
    w = np.concatenate([np.ones(400), np.zeros(100)]).astype(np.float32)
    res_pad = lloyd(
        jax.random.PRNGKey(3), jnp.asarray(padded), 4, weights=jnp.asarray(w), iters=15
    )
    # Padded garbage points must not attract centers.
    assert np.abs(np.asarray(res_pad.centers)).max() < 100.0


def test_kmedian_cost_uses_unsquared_distance():
    pts = np.array([[0.0, 0.0], [2.0, 0.0]], np.float32)
    c = jnp.asarray([[0.0, 0.0]], jnp.float32)
    assert float(clustering_cost(jnp.asarray(pts), c, median=True)) == pytest.approx(2.0)
    assert float(clustering_cost(jnp.asarray(pts), c, median=False)) == pytest.approx(4.0)


def test_algorithm1_beats_ignoring_stragglers(s1):
    pts, _, _ = s1
    rng = np.random.default_rng(0)
    s, t, k = 10, 3, 15
    alive = fixed_count_stragglers(s, t, rng)
    central = lloyd(jax.random.PRNGKey(0), jnp.asarray(pts), k, iters=30, median=True)
    redundant = bernoulli_assignment(len(pts), s, ell=2.0, rng=rng)
    out_res = resilient_kmedian(pts, k, redundant, alive, local_iters=10, coord_iters=25)
    out_ign = ignore_stragglers_kmedian(
        pts, k, singleton_assignment(len(pts), s), alive, local_iters=10, coord_iters=25
    )
    c_central = float(central.cost)
    # Theorem 3 bound with the achieved delta (generous empirical slack).
    assert out_res.cost <= 3.0 * (1.0 + out_res.recovery.delta) * c_central
    # Redundancy must not be worse than ignoring stragglers (paper Fig 1).
    assert out_res.cost <= out_ign.cost * 1.05


def test_algorithm1_fr_assignment_exact_band(s1):
    pts, _, _ = s1
    a = fractional_repetition_assignment(len(pts), 12, 3)
    alive = fixed_count_stragglers(12, 2, np.random.default_rng(5))
    out = resilient_kmedian(pts, 15, a, alive, local_iters=8, coord_iters=20)
    assert out.recovery.feasible
    assert out.recovery.delta <= 1e-6  # FR: exact recovery band


def test_sensitivity_coreset_epsilon_band():
    pts, _, _ = gaussian_mixture(2000, 5, 4, rng=np.random.default_rng(3))
    x = jnp.asarray(pts)
    cs = sensitivity_coreset(jax.random.PRNGKey(0), x, k=5, m=500)
    rng = np.random.default_rng(4)
    # ε-coreset property over random center sets (empirical band).
    for _ in range(5):
        C = jnp.asarray(rng.normal(size=(5, 4)), jnp.float32)
        full = float(clustering_cost(x, C))
        approx = float(clustering_cost(cs.points, C, weights=cs.weights))
        assert abs(approx - full) / full < 0.35
    # Total weight approximates n.
    assert float(cs.weights.sum()) == pytest.approx(2000, rel=0.3)


def test_uniform_coreset_weight_normalization():
    pts, _, _ = gaussian_mixture(1000, 3, 2, rng=np.random.default_rng(6))
    cs = uniform_coreset(jax.random.PRNGKey(1), jnp.asarray(pts), 200)
    assert float(cs.weights.sum()) == pytest.approx(1000, rel=0.25)


def test_algorithm2_subspace_clustering_quality():
    pts, _ = planted_subspaces(900, 3, 8, 2, noise=0.01, rng=np.random.default_rng(7))
    a = bernoulli_assignment(len(pts), 8, ell=3.0, rng=np.random.default_rng(8))
    alive = fixed_count_stragglers(8, 2, np.random.default_rng(9))
    out = resilient_subspace_clustering(pts, 2, 3, a, alive, coreset_size=256)
    central = lloyd_subspace(jax.random.PRNGKey(2), jnp.asarray(pts), 3, 2)
    # Theorem 4: within alpha(1+8delta) of optimal; empirically compare to the
    # same solver run centrally, with generous slack for coreset noise.
    assert out.cost <= max(5.0 * float(central.cost), float(central.cost) + 2.0)


def test_algorithm2_r0_reduces_to_kmeans():
    pts, _, _ = gaussian_mixture(600, 4, 5, rng=np.random.default_rng(10))
    sol = lloyd_subspace(jax.random.PRNGKey(0), jnp.asarray(pts), 4, 0)
    km = lloyd(jax.random.PRNGKey(0), jnp.asarray(pts), 4, iters=15)
    assert float(sol.cost) <= 1.5 * float(km.cost) + 1e-3


def test_relaxed_coreset_rank_formula():
    assert relaxed_coreset_rank(5, 1.0) == 9  # r + r/δ − 1
    assert relaxed_coreset_rank(2, 0.5) == 5
    assert relaxed_coreset_rank(1, 0.25) == 4


def test_algorithm3_pca_theorem5_band():
    pts, _ = planted_subspaces(800, 1, 24, 4, noise=0.05, rng=np.random.default_rng(11))
    pts = pts - pts.mean(0, keepdims=True)
    delta = 0.25
    # ell high enough that every shard keeps a live replica after t=3 of 10
    # nodes straggle (P[shard uncovered] = (1−p_a)^7 ≈ 1e-5 at p_a = 0.8).
    a = bernoulli_assignment(len(pts), 10, ell=8.0, rng=np.random.default_rng(12))
    alive = fixed_count_stragglers(10, 3, np.random.default_rng(13))
    out = resilient_pca(pts, 4, delta, a, alive)
    opt = float(pca_cost(jnp.asarray(pts), centralized_pca(jnp.asarray(pts), 4)))
    assert out.recovery.feasible
    # Theorem 5: cost ≤ (1+4δ)·OPT — with the achieved (LP) delta.
    band = 1.0 + 4.0 * max(delta, out.recovery.delta)
    assert out.cost <= band * opt * 1.05 + 1e-6
    # Communication is r1·|R| rows, independent of n.
    assert out.sketch_rows <= out.r1 * int(alive.sum())


def test_algorithm3_pca_exact_when_no_stragglers():
    pts, _ = planted_subspaces(500, 1, 16, 3, noise=0.0, rng=np.random.default_rng(14))
    pts = pts - pts.mean(0, keepdims=True)  # linear PCA; remove affine offset
    a = fractional_repetition_assignment(len(pts), 8, 2)
    out = resilient_pca(pts, 3, 0.5, a, np.ones(8, dtype=bool))
    # Noise-free planted subspace: residual ≈ 0.
    assert out.cost <= 1e-3 * float(jnp.sum(jnp.asarray(pts) ** 2))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1000))
def test_resilient_kmedian_never_catastrophic(seed):
    """Property: under the Theorem-6 regime the resilient cost is bounded by a
    modest multiple of the centralized heuristic — never the unbounded blowup
    the ignore-stragglers scheme exhibits when clusters are dropped."""
    rng = np.random.default_rng(seed)
    pts, _, _ = gaussian_mixture(600, 8, 2, spread=0.02, rng=rng)
    a = bernoulli_assignment(len(pts), 10, ell=3.0, rng=rng)
    alive = fixed_count_stragglers(10, 3, rng)
    out = resilient_kmedian(pts, 8, a, alive, local_iters=8, coord_iters=20)
    central = lloyd(jax.random.PRNGKey(seed), jnp.asarray(pts), 8, iters=20, median=True)
    assert out.cost <= 3.0 * (1.0 + max(out.recovery.delta, 0.5)) * float(central.cost)
